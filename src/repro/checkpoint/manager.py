"""Checkpointing: atomic, async, resharding-aware (fault tolerance + elasticity).

Design (DESIGN.md Sec. 5):
  * layout: <dir>/step_<N>/ with one .npy per leaf + manifest.json (treedef,
    shapes, dtypes, step, extra metadata);
  * atomicity: written to step_<N>.tmp then os.rename'd — a crash mid-write never
    corrupts the latest checkpoint (restart-safe);
  * async: `save(..., blocking=False)` snapshots to host (device_get) on the
    caller thread — the brief pause — then writes to disk on a background thread
    so training resumes during I/O;
  * resharding restore: `restore(..., shardings=...)` device_puts each leaf with
    the *target* sharding, so a checkpoint taken on one mesh restarts on another
    (elastic re-scale) or on a different device count;
  * retention: keep the last `keep` checkpoints, never deleting a checkpoint that
    has not been fully committed.

Multi-host note: this is a single-controller implementation (device_get gathers
to the host).  On a real multi-host pod each host would write only
`addressable_shards` under the same manifest; the format reserves a `shard` field
for that (see DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from ml_dtypes import bfloat16 as ml_bfloat16


def _flatten_with_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    paths_vals, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, val in paths_vals:
        name = "/".join(_key_str(k) for k in path) or "leaf"
        out.append((name, val))
    return out, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[Dict] = None,
             blocking: bool = True,
             specs: Optional[Dict[str, str]] = None) -> None:
        """Snapshot `tree` (any pytree of arrays) at `step`.

        `specs` maps leaf names (e.g. ``"opt/m"``) to a shard-spec string
        recorded in the manifest's ``shard`` field — e.g. the ZeRO trainer's
        ``"zero-carrier:data"`` for carrier-sharded optimizer moments.  The
        arrays written are still the full (gathered) values; the spec is
        layout *metadata* that `restore` checks so a sharded checkpoint is
        never silently loaded into a replicated trainer or vice versa.
        """
        self.wait()  # one async save in flight at a time
        specs = specs or {}
        leaves, _ = _flatten_with_paths(tree)
        # snapshot to host memory now (cheap vs. I/O); training may proceed after.
        # bf16 has no native numpy dtype: store as a uint16 view + logical dtype.
        def to_host(v):
            a = np.asarray(jax.device_get(v))
            if a.dtype == ml_bfloat16:
                return a.view(np.uint16), "bfloat16"
            return a, str(a.dtype)
        host = [(name,) + to_host(v) for name, v in leaves]
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": dt,
                 "shard": specs.get(n)}
                for n, a, dt in host
            ],
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, (name, arr, _dt) in enumerate(host):
                np.save(tmp / f"leaf_{i}.npy", arr)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as e:  # surfaced on next wait()
                    self._last_error = e
            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError(f"async checkpoint write failed: {err}")

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp"):
                continue
            if not (p / "manifest.json").exists():
                continue  # incomplete
            steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None,
                specs: Optional[Dict[str, str]] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of `tree_like`.  `shardings` (same pytree
        structure or a pytree of NamedShardings) reshard onto the current mesh.

        `specs` declares which leaves the *caller* expects to be shard-laid-out
        (same name -> spec-string mapping as `save`).  A mismatch against the
        manifest raises before any leaf is loaded: restoring a ZeRO
        carrier-sharded checkpoint into a replicated trainer (or the reverse)
        would reinterpret optimizer moments under the wrong layout, not just
        the wrong shape."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten_with_paths(tree_like)
        specs = specs or {}
        saved_specs = {m["name"]: m.get("shard") for m in manifest["leaves"]
                       if m.get("shard")}
        if saved_specs and not specs:
            raise ValueError(
                f"checkpoint step_{step} carries shard-laid-out leaves "
                f"{sorted(saved_specs)} (specs {sorted(set(saved_specs.values()))}) "
                f"but the restore target expects replicated state — a ZeRO "
                f"(zero=True) checkpoint cannot restore into a replicated "
                f"trainer; rebuild with zero=True or re-save replicated")
        if specs and not saved_specs:
            raise ValueError(
                f"restore target expects shard-laid-out leaves "
                f"{sorted(specs)} but checkpoint step_{step} holds replicated "
                f"state — a replicated checkpoint cannot restore into a ZeRO "
                f"(zero=True) trainer; rebuild without zero or re-save sharded")
        for name in sorted(set(specs) | set(saved_specs)):
            want, got = specs.get(name), saved_specs.get(name)
            if got != want:
                raise ValueError(
                    f"leaf {name}: checkpoint shard spec {got!r} != expected "
                    f"{want!r} — sharded layouts must match exactly (same DP "
                    f"axes and carrier geometry) to restore")
        if len(leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, target structure "
                f"{len(leaves)} — incompatible trees")
        sh_leaves = None
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        out = []
        for i, (meta, (name, like)) in enumerate(zip(manifest["leaves"], leaves)):
            arr = np.load(d / f"leaf_{i}.npy")
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_bfloat16)
            if list(arr.shape) != list(like.shape):
                raise ValueError(f"leaf {name}: checkpoint shape {arr.shape} != "
                                 f"target {like.shape}")
            if arr.dtype != like.dtype:
                arr = arr.astype(like.dtype)
            if sh_leaves is not None and sh_leaves[i] is not None:
                out.append(jax.device_put(arr, sh_leaves[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    # ------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp") and (p / "manifest.json").exists())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
