from .manager import CheckpointManager
