"""Interconnect characterization — the paper's measurement campaign, end to end.

Runs the {mechanism} x {pattern} x {size} matrix on a forced-multi-device mesh
(the intra-node analog), prints the derived observations, then projects the
at-scale figures (9/10/13) from the calibrated cost models.

  PYTHONPATH=src python examples/characterize_comm.py [--devices 8]

NOTE: spawns itself with XLA_FLAGS to get multiple host devices.
"""
import argparse
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def inner(n_devices: int):
    import jax
    import repro.compat  # jax API shims before touching jax.sharding
    from jax.sharding import AxisType

    from repro.core.bench import print_records, write_csv
    from repro.core.calibrate import (CalibrationProfile, compare_to_model,
                                      plan_table_deltas, run_calibration)
    from repro.core.characterize import characterize_mesh, project_at_scale
    from repro.core.commplan import CommPlan
    from repro.core.costmodel import make_comm_model
    from repro.core.noise import NoiseModel

    mesh = jax.make_mesh((n_devices,), ("x",), axis_types=(AxisType.Auto,))
    print(f"== measuring on {n_devices} host devices (ICI analog) ==")
    model = make_comm_model("tpu_v5e")
    report = characterize_mesh(mesh, "x", sizes=(1 << 12, 1 << 16, 1 << 20),
                               iters=20, model=model)
    print_records(report.records)
    out = ROOT / "artifacts" / "bench"
    out.mkdir(parents=True, exist_ok=True)
    write_csv(str(out / "characterization.csv"), report.records)
    print("\n== observations (local evidence) ==")
    for k, v in report.observations.items():
        print(f"  {k}: {v}")
    print("\n== at-scale projection (Figs. 9/10/13 analog) ==")
    for row in project_at_scale("tpu_v5e", noise=NoiseModel.tpu_dcn()):
        print("  ", row)

    print("\n== calibration (measured alpha-beta fits vs the analytic model) ==")
    profile, records = run_calibration(mesh, "x",
                                       sizes=(1 << 12, 1 << 16, 1 << 20),
                                       iters=20, model=model,
                                       base_records=report.records)
    calib_path = out / "calibration.json"
    profile.save(str(calib_path))
    assert CalibrationProfile.load(str(calib_path)) == profile
    write_csv(str(out / "calibration_records.csv"), records)
    print(f"  artifact: {calib_path} "
          f"({len(profile.params)} fitted (mechanism, pattern, regime) keys)")
    for row in compare_to_model(profile, model):
        print(f"  {row['key']:38s} measured={row['measured_us']:9.1f}us "
              f"analytic={row['analytic_us']:9.1f}us "
              f"ratio={row['ratio']:7.2f} r2={row['r2']:.2f}")
    topo = model.two_level or model.graph
    analytic_plan = CommPlan.from_topology(topo, profile=model.profile)
    calibrated_plan = CommPlan.from_topology(topo, profile=model.profile,
                                             calibration=profile)
    deltas = plan_table_deltas(analytic_plan, calibrated_plan)
    print(f"  plan entries re-ranked by the measured profile: {len(deltas)} "
          f"(bucket {analytic_plan.bucket_bytes >> 10} -> "
          f"{calibrated_plan.bucket_bytes >> 10} KiB)")
    for d in deltas[:8]:
        print(f"    {d}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--_inner", action="store_true")
    args = ap.parse_args()
    if args._inner:
        inner(args.devices)
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    sys.exit(subprocess.call([sys.executable, __file__, "--devices",
                              str(args.devices), "--_inner"], env=env))


if __name__ == "__main__":
    main()
