"""Quickstart: train a reduced LM for a few steps, checkpoint, resume, decode.

  PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m] [--steps 20]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig
from repro.runtime.serve import BatchedServer, ServeConfig
from repro.runtime.train import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt", default="artifacts/quickstart_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M (reduced)")

    trainer = Trainer(cfg, shape,
                      OptConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=args.steps),
                      TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 2, 1),
                                  ckpt_dir=args.ckpt, log_every=5))
    result = trainer.run()
    losses = [m["loss"] for m in result["metrics"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    # resume from the checkpoint (restart path)
    trainer2 = Trainer(cfg, shape, OptConfig(peak_lr=1e-3, warmup_steps=5,
                                             decay_steps=args.steps + 5),
                       TrainConfig(steps=args.steps + 5, ckpt_every=0,
                                   ckpt_dir=args.ckpt, log_every=5))
    result2 = trainer2.run(resume=True)
    print(f"resumed from step {result['final_step']} -> {result2['final_step']}")

    # greedy decode with the trained weights
    params, _, _ = trainer2.restore()
    server = BatchedServer(cfg, max_seq=96, batch_size=2, params=params["params"]
                           if isinstance(params, dict) and "params" in params else params)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 8)).astype(np.int32)
    out = server.generate(prompts, ServeConfig(max_new_tokens=8))
    print("generated ids:", out[0].tolist())


if __name__ == "__main__":
    main()
