"""End-to-end training driver: ~100M-class model for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --steps 300 \
      --seq 128 --batch 8 [--full]   # --full trains the real config (slow on CPU)

Demonstrates: config selection (--arch works for all 10), deterministic data,
async checkpointing + resume, straggler logging, cosine schedule.

MoE quickstart (--moe): an expert-parallel step compiled from the StepProgram
IR — token dispatch/combine run as *planned* alltoalls through the plan's
per-tier tables (set XLA_FLAGS=--xla_force_host_platform_device_count=4 to
watch the exchange cross 4 fake devices):

  PYTHONPATH=src python examples/train_lm.py --moe --steps 20
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, list_configs
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig
from repro.runtime.train import Trainer, TrainConfig


def run_moe(args):
    """Expert-parallel MoE quickstart: build the `moe_alltoall` StepProgram,
    compile it with `build_program_step`, and train the EP layer directly.
    The DP axis doubles as the expert axis; the plan's stats show which
    alltoall algorithm the per-tier tables dispatched."""
    import jax
    import repro.compat  # noqa: F401  (jax API shims)
    from jax.sharding import AxisType

    from repro.core import program as prg
    from repro.core.autotune import CollectivePolicy
    from repro.optim import adamw
    from repro.runtime import moe_step as ms
    from repro.runtime import steps as rsteps

    cfg = get_config("deepseek-moe-16b").reduced()
    # the EP axis must divide the expert count; wider hosts use the first
    # n_experts devices for the exchange
    n = min(jax.device_count(), cfg.n_experts)
    mesh = jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,),
                         devices=jax.devices()[:n])
    policy = CollectivePolicy.from_model()
    program = prg.moe_step_program()
    step = rsteps.build_program_step(cfg, adamw.OptConfig(peak_lr=args.lr),
                                     mesh, program, policy=policy)
    print(f"program: {program.name} "
          f"({' -> '.join(nd.kind for nd in program.nodes)}) on {n} device(s)")

    params = ms.moe_ep_params(cfg, jax.random.PRNGKey(0))
    batch = ms.moe_ep_batch(cfg, jax.random.PRNGKey(1), max(args.batch, n), 32)
    opt_state = adamw.init_opt_state(params)
    err = step.init_error_state(params)
    for i in range(args.steps):
        params, opt_state, metrics, err = step(params, opt_state, batch, err)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"aux {float(metrics['aux_loss']):.4f}")
    print("plan stats:", policy._as_plan().stats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_configs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    ap.add_argument("--moe", action="store_true",
                    help="expert-parallel MoE quickstart: the moe_alltoall "
                         "StepProgram with planned token dispatch/combine")
    args = ap.parse_args()
    if args.moe:
        return run_moe(args)

    cfg = get_config(args.arch) if args.full else get_config(args.arch).reduced()
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    trainer = Trainer(
        cfg, shape,
        OptConfig(peak_lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                  decay_steps=args.steps),
        TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                    ckpt_dir=args.ckpt, log_every=10, ckpt_async=True,
                    straggler_threshold=2.5),
    )
    t0 = time.time()
    result = trainer.run(resume=args.resume)
    dt = time.time() - t0
    losses = [m["loss"] for m in result["metrics"]]
    toks = len(losses) * args.batch * args.seq
    print(f"\ndone: {result['final_step']} steps in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s); loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers observed: {result['straggler_events']}")


if __name__ == "__main__":
    main()
