"""End-to-end training driver: ~100M-class model for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --steps 300 \
      --seq 128 --batch 8 [--full]   # --full trains the real config (slow on CPU)

Demonstrates: config selection (--arch works for all 10), deterministic data,
async checkpointing + resume, straggler logging, cosine schedule.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, list_configs
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig
from repro.runtime.train import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_configs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_config(args.arch).reduced()
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    trainer = Trainer(
        cfg, shape,
        OptConfig(peak_lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                  decay_steps=args.steps),
        TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                    ckpt_dir=args.ckpt, log_every=10, ckpt_async=True,
                    straggler_threshold=2.5),
    )
    t0 = time.time()
    result = trainer.run(resume=args.resume)
    dt = time.time() - t0
    losses = [m["loss"] for m in result["metrics"]]
    toks = len(losses) * args.batch * args.seq
    print(f"\ndone: {result['final_step']} steps in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s); loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers observed: {result['straggler_events']}")


if __name__ == "__main__":
    main()
