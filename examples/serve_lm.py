"""Batched serving example: prefill + decode with the sequence-sharded KV cache.

  PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m --batch 4 \
      --prompt-len 16 --new-tokens 16 [--temperature 0.8]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs import get_config, list_configs
from repro.runtime.serve import BatchedServer, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_configs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    max_seq = args.prompt_len + args.new_tokens + 8
    server = BatchedServer(cfg, max_seq=max_seq, batch_size=args.batch)
    rng = np.random.RandomState(0)
    if cfg.n_codebooks:
        prompts = rng.randint(0, cfg.vocab,
                              (args.batch, args.prompt_len, cfg.n_codebooks)).astype(np.int32)
    else:
        prompts = rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.time()
    out = server.generate(prompts, ServeConfig(max_new_tokens=args.new_tokens,
                                               temperature=args.temperature))
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={out.shape[1]}: {args.batch*out.shape[1]/dt:.1f} tok/s")
    for i in range(min(args.batch, 2)):
        ids = out[i].reshape(out.shape[1], -1)[:, 0].tolist()
        print(f"  request {i}: {ids}")


if __name__ == "__main__":
    main()
